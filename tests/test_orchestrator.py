"""Orchestrator tests (paper §3.5, Alg. 1): hierarchy construction,
local-first mapping, escalation, constraint protection, overhead ledger;
plus whole-session parity of the fused wave-batched walk against the
sequential per-task oracle (``REPRO_FUSED_WALK=0``)."""
import pytest

from repro.core import (ActiveLedger, OrcConfig, Orchestrator, Traverser,
                        build_orchestrators, build_testbed, heye_traverser)
from repro.core.topology import make_task


@pytest.fixture()
def setup():
    tb = build_testbed(edge_counts={"orin_agx": 1, "orin_nano": 1},
                       server_counts={"server1": 1, "server2": 1})
    trav = heye_traverser(tb.graph)
    root = build_orchestrators(tb.graph, trav)
    return tb, trav, root


def test_hierarchy_matches_fig4b(setup):
    tb, _, root = setup
    # root has two cluster ORCs (edge + server), each with device children
    assert len(root.children) == 2
    groups = sorted(c.group for c in root.children)
    assert groups == ["edge_cluster", "server_cluster"]
    devices = [o.group for c in root.children for o in c.children]
    assert set(devices) == set(tb.edges) | set(tb.servers)
    # device ORCs know their own PUs only (resource segregation)
    for c in root.children:
        for dev in c.children:
            assert dev.leaf_pus
            assert all(p.startswith(dev.group + ".") for p in dev.leaf_pus)
    # cluster and root ORCs hold no PUs directly
    assert not root.leaf_pus
    assert all(not c.leaf_pus for c in root.children)


def test_local_first_assignment(setup):
    tb, _, root = setup
    e = tb.edges[0]
    orc = root.find_device_orc(e)
    t = make_task("capture", origin=e, deadline=0.1)
    res = orc.map_batch([t])[0]
    assert res is not None
    assert res.pu.startswith(e + ".")       # stayed local
    assert res.hops == 0                    # no remote queries
    assert t.assigned_pu == res.pu


def test_escalation_to_server(setup):
    tb, _, root = setup
    e = tb.edges[1]                         # orin_nano: render at 90 ms
    orc = root.find_device_orc(e)
    t = make_task("render", origin=e, deadline=0.030, input_bytes=4e3)
    res = orc.map_batch([t])[0]
    assert res is not None
    dev = tb.graph.device_of(res.pu).name
    assert dev in tb.servers                # escalated off-device
    assert res.hops > 0                     # remote messages counted
    assert res.overhead > 0.0


def test_pinned_stays_local(setup):
    tb, _, root = setup
    e = tb.edges[1]
    orc = root.find_device_orc(e)
    t = make_task("capture", origin=e, deadline=0.1)
    t.attrs["pinned"] = True
    res = orc.map_batch([t])[0]
    assert tb.graph.device_of(res.pu).name == e


def test_existing_task_constraints_protected(setup):
    """Alg. 1 l.15: a new task must not break a resident task's deadline."""
    tb, trav, root = setup
    e = tb.edges[0]
    orc = root.find_device_orc(e)
    gpu = f"{e}.gpu"
    # resident: a GPU task with a deadline it barely meets
    sa = tb.graph.nodes[gpu].predict(make_task("dnn"))
    resident = make_task("dnn", origin=e, deadline=sa * 1.05)
    pred = trav.predict_task(resident, gpu, [])
    orc.ledger.add(resident, gpu, pred, now=0.0)
    # a new heavy task on the same GPU would slow the resident beyond 1.05x
    newbie = make_task("dnn", origin=e, deadline=10.0)
    ok, _ = orc._check_constraints(newbie, gpu, now=0.0)
    assert not ok
    # but a task on a PU that does not contend hard is fine
    ok2, _ = orc._check_constraints(
        make_task("capture", origin=e, deadline=10.0), f"{e}.cpu0", now=0.0)
    assert ok2


def test_best_effort_when_nothing_fits(setup):
    tb, _, root = setup
    e = tb.edges[0]
    orc = root.find_device_orc(e)
    t = make_task("render", origin=e, deadline=1e-9)   # impossible deadline
    res = orc.map_batch([t])[0]
    assert res is not None                  # degraded, not dropped
    t2 = make_task("render", origin=e, deadline=1e-9)
    cfg = OrcConfig(allow_best_effort=False)
    orc2 = build_orchestrators(tb.graph, heye_traverser(tb.graph),
                               config=cfg).find_device_orc(e)
    assert orc2.map_batch([t2])[0] is None


def test_ledger_prune_and_remove(setup):
    tb, trav, root = setup
    e = tb.edges[0]
    led = ActiveLedger()
    t = make_task("dnn", origin=e)
    led.add(t, f"{e}.gpu", trav.predict_task(t, f"{e}.gpu", []), now=0.0)
    assert led.count(f"{e}.gpu") == 1
    led.prune(now=1e9)
    assert led.count(f"{e}.gpu") == 0
    led.add(t, f"{e}.gpu", trav.predict_task(t, f"{e}.gpu", []), now=0.0)
    led.remove(t)
    assert led.count(f"{e}.gpu") == 0


def test_first_fit_cheaper_than_best_fit(setup):
    tb, trav, _ = setup
    e = tb.edges[0]
    t_bf = make_task("pose_pred", origin=e, deadline=0.5)
    t_ff = make_task("pose_pred", origin=e, deadline=0.5)
    best = build_orchestrators(tb.graph, trav, config=OrcConfig())
    first = build_orchestrators(tb.graph, trav,
                                config=OrcConfig(objective="first_fit"))
    r_bf = best.find_device_orc(e).map_batch([t_bf])[0]
    r_ff = first.find_device_orc(e).map_batch([t_ff])[0]
    assert r_ff.queries <= r_bf.queries


def test_dead_pu_not_assigned(setup):
    tb, trav, _ = setup
    e = tb.edges[0]
    tb.graph.mark_dead(f"{e}.gpu")
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    orc = root.find_device_orc(e)
    t = make_task("dnn", origin=e, deadline=1.0)
    res = orc.map_batch([t])[0]
    assert res is not None and res.pu != f"{e}.gpu"
    tb.graph.mark_alive(f"{e}.gpu")


def test_overhead_scales_with_remote_search(setup):
    tb, _, root = setup
    e = tb.edges[1]
    orc = root.find_device_orc(e)
    local = orc.map_batch([make_task("capture", origin=e, deadline=1.0)])[0]
    remote = orc.map_batch([make_task("render", origin=e, deadline=0.030,
                                      input_bytes=4e3)])[0]
    assert remote.overhead > local.overhead


# ---------------------------------------------------------------------------
# fused wave-batched walk vs the sequential per-task oracle
# ---------------------------------------------------------------------------
# ``REPRO_FUSED_WALK=1`` (default) lowers every mapping wave to array scans
# over the compiled ORC tree; ``=0`` keeps the seed's Python object walk.
# The contract is bit-identical *decisions*: pu, standalone, factor, comm,
# queries and hops match exactly, overhead to 1e-9 (the fused reduce sums
# the same terms in a different association order).

_PARITY_EDGES = {"orin_agx": 2, "xavier_agx": 1, "orin_nano": 2,
                 "xavier_nx": 1}
_PARITY_SERVERS = {"server1": 1, "server2": 1}


def _run_mode(monkeypatch, mode, workload, churn=None, counts=None):
    """Map ``workload(tb)``'s batches through a fresh session in one walk
    mode — ``"sharded"`` (group-parallel driver), ``"fused"``
    (single-shard fused walk), ``"oracle"`` (sequential object walk);
    ``True``/``False`` alias fused/oracle — with optional ``churn(tb, i)``
    graph mutations between batches.  Returns one list of result rows per
    batch, in sorted-uid order (uids differ between twin sessions;
    creation order does not)."""
    from repro.core import SchedulerSession
    if mode is True:
        mode = "fused"
    elif mode is False:
        mode = "oracle"
    monkeypatch.setenv("REPRO_FUSED_WALK",
                       "0" if mode == "oracle" else "1")
    monkeypatch.setenv("REPRO_SHARDED_WALK",
                       "1" if mode == "sharded" else "0")
    tb = build_testbed(edge_counts=dict(counts[0] if counts
                                        else _PARITY_EDGES),
                       server_counts=dict(counts[1] if counts
                                          else _PARITY_SERVERS))
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    sess = SchedulerSession(tb.graph, root)
    batches = []
    for i, batch in enumerate(workload(tb)):
        sess.submit(batch)
        res = sess.map_pending()
        batches.append([
            (res[u].pu, res[u].prediction.standalone,
             res[u].prediction.factor, res[u].prediction.comm,
             res[u].queries, res[u].hops, res[u].overhead)
            for u in sorted(res)])
        if churn is not None:
            churn(tb, i)
    return batches


def _assert_parity(fused_batches, oracle_batches):
    assert len(fused_batches) == len(oracle_batches)
    for fb, ob in zip(fused_batches, oracle_batches):
        assert len(fb) == len(ob)
        for f, o in zip(fb, ob):
            assert f[:6] == o[:6]                     # exact decisions
            assert f[6] == pytest.approx(o[6], rel=1e-9, abs=1e-12)


def test_fused_walk_matches_oracle_mining(monkeypatch):
    """Fig. 13 workload: parallel sensor readings, deadline-driven
    escalation off the weak edges, two readings -> two release waves."""
    from repro.core import mining_workload
    wl = lambda tb: [mining_workload(tb, n_sensors=18, n_readings=2)]
    _assert_parity(_run_mode(monkeypatch, True, wl),
                   _run_mode(monkeypatch, False, wl))


def test_fused_walk_matches_oracle_vr(monkeypatch):
    """Fig. 7 workload: serial CFGs with pinned stages and inter-device
    src_devices provenance flowing producer -> consumer."""
    from repro.core import vr_workload
    wl = lambda tb: [vr_workload(tb, n_frames=3)]
    _assert_parity(_run_mode(monkeypatch, True, wl),
                   _run_mode(monkeypatch, False, wl))


def test_fused_walk_parity_across_churn(monkeypatch):
    """mark_dead + set_bandwidth between mapping batches: the apply_delta'd
    snapshot bumps device epochs, so every fused-side cache (scan plans,
    core states, canonical factor entries) must refresh — parity with the
    oracle, which re-reads the graph per task, proves none went stale."""
    from repro.core import mining_workload

    def wl(tb):
        return [mining_workload(tb, n_sensors=12, n_readings=1),
                mining_workload(tb, n_sensors=12, n_readings=1)]

    dead = {}

    def churn(tb, i):
        if i == 0:
            dead["pu"] = f"{tb.edges[0]}.gpu"
            tb.graph.mark_dead(dead["pu"])
            tb.graph.set_bandwidth(f"link_{tb.edges[1]}", 1e6)

    fused = _run_mode(monkeypatch, True, wl, churn=churn)
    oracle = _run_mode(monkeypatch, False, wl, churn=churn)
    _assert_parity(fused, oracle)
    # and the churn actually bit: nothing lands on the dead PU afterwards
    assert all(row[0] != dead["pu"] for row in fused[1])


def test_set_bandwidth_invalidates_fused_comm(monkeypatch):
    """An identical escalating task mapped before and after a bandwidth
    collapse must see the new comm cost through the fused path (caches are
    keyed per compiled snapshot, not per graph)."""

    def wl(tb):
        e = next(x for x in tb.edges if tb.edge_kind[x] == "orin_nano")
        mk = lambda: [make_task("render", origin=e, deadline=0.030,
                                input_bytes=4e3)]
        return [mk(), mk()]

    def churn(tb, i):
        if i == 0:
            e = next(x for x in tb.edges if tb.edge_kind[x] == "orin_nano")
            tb.graph.set_bandwidth(f"link_{e}", 1e6)

    fused = _run_mode(monkeypatch, True, wl, churn=churn)
    oracle = _run_mode(monkeypatch, False, wl, churn=churn)
    _assert_parity(fused, oracle)
    before, after = fused[0][0], fused[1][0]
    assert after[3] != before[3]            # comm reflects the new network


# ---------------------------------------------------------------------------
# group-sharded walk vs the fused single-shard walk
# ---------------------------------------------------------------------------
# ``REPRO_SHARDED_WALK=1`` (default) partitions the snapshot and ledger per
# root-child ORC group and drives independent groups' walks on host threads,
# reconciling only at the root (NCR) boundary; ``=0`` keeps the fused
# single-shard walk.  The contract is **bit-identical mappings** — stricter
# than the fused-vs-oracle 1e-9 overhead tolerance, because the sharded
# driver runs the very same reduces over the very same arrays, only
# partitioned.

# Fig. 13 mining topology at mult=64 (mining_counts(64) in
# benchmarks/scaling.py): the scale ROADMAP item 2 targets
_X64_EDGES = {"orin_agx": 192, "xavier_agx": 192, "orin_nano": 128,
              "xavier_nx": 128}
_X64_SERVERS = {"server1": 64, "server2": 64, "server3": 64}


def _assert_bit_identical(sharded_batches, fused_batches):
    assert len(sharded_batches) == len(fused_batches)
    for sb, fb in zip(sharded_batches, fused_batches):
        assert sb == fb


def test_sharded_walk_matches_fused_mining_x64(monkeypatch):
    """Whole-session Fig. 13 mining at mult=64: the group-sharded driver
    must reproduce the fused single-shard mappings bit for bit."""
    from repro.core import mining_workload
    wl = lambda tb: [mining_workload(tb, n_sensors=256, n_readings=1)]
    _assert_bit_identical(
        _run_mode(monkeypatch, "sharded", wl,
                  counts=(_X64_EDGES, _X64_SERVERS)),
        _run_mode(monkeypatch, "fused", wl,
                  counts=(_X64_EDGES, _X64_SERVERS)))


def test_sharded_walk_matches_fused_vr_x64(monkeypatch):
    """Fig. 7 VR (serial CFGs, pinned stages, src_devices provenance) at
    the mult=64 fleet, bit-identical across the sharded driver."""
    from repro.core import vr_workload
    wl = lambda tb: [vr_workload(tb, n_frames=2)]
    _assert_bit_identical(
        _run_mode(monkeypatch, "sharded", wl,
                  counts=(_X64_EDGES, _X64_SERVERS)),
        _run_mode(monkeypatch, "fused", wl,
                  counts=(_X64_EDGES, _X64_SERVERS)))


def test_sharded_walk_parity_across_churn(monkeypatch):
    """mark_dead + set_bandwidth between waves: apply_delta clones the
    snapshot, so the sharded views and ledger shard maps must re-derive
    against the new clone — bit-identical to the fused walk throughout."""
    from repro.core import mining_workload

    def wl(tb):
        return [mining_workload(tb, n_sensors=12, n_readings=1),
                mining_workload(tb, n_sensors=12, n_readings=1)]

    dead = {}

    def churn(tb, i):
        if i == 0:
            dead["pu"] = f"{tb.edges[0]}.gpu"
            tb.graph.mark_dead(dead["pu"])
            tb.graph.set_bandwidth(f"link_{tb.edges[1]}", 1e6)

    sharded = _run_mode(monkeypatch, "sharded", wl, churn=churn)
    fused = _run_mode(monkeypatch, "fused", wl, churn=churn)
    _assert_bit_identical(sharded, fused)
    assert all(row[0] != dead["pu"] for row in sharded[1])


def test_sharded_cross_group_escalation(monkeypatch):
    """A deadline only servers can meet forces the walk out of the edge
    group: the escalation must cross the ORC boundary through the root's
    cross-group scan (serial boundary reconciliation) and still match the
    fused walk bit for bit."""

    def wl(tb):
        e = next(x for x in tb.edges if tb.edge_kind[x] == "orin_nano")
        return [[make_task("render", origin=e, deadline=0.030,
                           input_bytes=4e3) for _ in range(3)]]

    sharded = _run_mode(monkeypatch, "sharded", wl)
    fused = _run_mode(monkeypatch, "fused", wl)
    _assert_bit_identical(sharded, fused)
    # the mapping actually crossed groups (edge origin -> server PU)
    assert all(row[0].split(".")[0].startswith("server")
               for row in sharded[0])
    assert all(row[5] > 0 for row in sharded[0])       # hops charged


def test_sharded_session_state(monkeypatch):
    """The sharded session installs a ShardedLedger over the root-child
    groups, and the shared counters (engine opens, recompiles, factor
    cache) aggregate across shards exactly as in the monolithic setup."""
    from repro.core import SchedulerSession, mining_workload
    from repro.core.orchestrator import ShardedLedger
    monkeypatch.setenv("REPRO_FUSED_WALK", "1")
    monkeypatch.setenv("REPRO_SHARDED_WALK", "1")
    tb = build_testbed(edge_counts=dict(_PARITY_EDGES),
                       server_counts=dict(_PARITY_SERVERS))
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    sess = SchedulerSession(tb.graph, root)
    assert isinstance(root.ledger, ShardedLedger)
    assert len(root.ledger.shards) == len(root.children) >= 2
    # every device ORC routes through the same sharded ledger facade
    assert all(o.ledger is root.ledger for o in root.iter_tree())
    sess.submit(mining_workload(tb, n_sensors=8, n_readings=1))
    res = sess.map_pending()
    assert res and all(r is not None for r in res.values())
    # ledger totals aggregate across shards
    assert len(root.ledger) == sum(len(s) for s in root.ledger.shards)
    assert len(root.ledger) == len(res)
    # shared counters see the whole run, not one shard's slice
    assert root.factor_cache_hits + root.factor_cache_misses > 0
    # sharding never forces extra snapshot recompiles
    assert tb.graph.recompile_count <= 1
    stats = sess.execute()
    assert sess.engine_opens <= 1
    assert stats is not None


def test_sharded_hwgraph_slicing():
    """ShardedHWGraph unit surface: PU index remap, per-group NCR blocks,
    block-diagonal validation, and device -> shard lookup."""
    import numpy as np
    from repro.core.compiled import ShardedHWGraph
    tb = build_testbed(edge_counts=dict(_PARITY_EDGES),
                       server_counts=dict(_PARITY_SERVERS))
    comp = tb.graph.compiled()
    groups = {"edge_cluster": list(tb.edges),
              "server_cluster": list(tb.servers)}
    sh = comp.sharded(groups)
    assert isinstance(sh, ShardedHWGraph)
    assert sh.n_shards == 2
    assert comp.sharded(groups) is sh          # cached per partition
    names = set()
    for shard in sh.shards:
        # remap: local PU names are exactly the global names at pu_idx
        assert [comp.pu_names[i] for i in shard.pu_idx] == shard.pu_names
        assert all(shard.local_index[n] == j
                   for j, n in enumerate(shard.pu_names))
        # per-group NCR block matches the global matrix's slice
        np.testing.assert_array_equal(
            shard.ncr_res, comp.ncr_res[np.ix_(shard.pu_idx, shard.pu_idx)])
        names.update(shard.pu_names)
        for d in shard.devices:
            assert sh.shard_of(d) == shard.name
    assert names == set(comp.pu_names)         # partition covers the fleet
    # cross-shard NCR entries are empty (-1): the partition is
    # block-diagonal by construction
    a, b = sh.shards
    assert (comp.ncr_res[np.ix_(a.pu_idx, b.pu_idx)] == -1).all()
    # a partition that splits one shared-resource device across groups
    # must be rejected
    e = tb.edges[0]
    bad = {"g1": [e], "g2": [d for d in tb.edges if d != e] + tb.servers}
    pus = [p for p in comp.pu_names if p.startswith(e + ".")]
    if len(pus) > 1 and not (
            comp.ncr_res[np.ix_(
                [comp.pu_index[pus[0]]],
                [comp.pu_index[p] for p in pus[1:]])] == -1).all():
        bad2 = {"g1": [e], "g2": [e]}          # overlapping groups
        with pytest.raises(ValueError):
            comp.sharded(bad2)
    with pytest.raises(ValueError):
        comp.sharded({"g1": [e], "g2": [e, *tb.servers]})


# ---------------------------------------------------------------------------
# Serving fast path (``REPRO_SERVE_FASTPATH``, default on): waves reuse one
# session-resident batch context — persistent scan states, canonical factor
# splices and incremental ledger views — instead of a cold per-wave rebuild,
# and single-task waves take the fused walk too.  The contract is the same
# bit-identical-decision parity as the fused walk itself, now across calls.


def test_resident_context_matches_cold_walk(monkeypatch):
    """Steady-state serving shape — a stream of single-task waves at
    advancing release instants — mapped through one resident context
    matches the cold per-wave object walk exactly."""
    kinds = ["svm", "mlp", "svm", "dnn", "svm", "mlp", "render", "svm"]

    def wl(tb):
        return [[make_task(k, origin=tb.edges[i % len(tb.edges)],
                           deadline=0.5, release_time=0.004 * i)]
                for i, k in enumerate(kinds)]

    monkeypatch.setenv("REPRO_SERVE_FASTPATH", "1")
    fast = _run_mode(monkeypatch, "fused", wl)
    monkeypatch.setenv("REPRO_SERVE_FASTPATH", "0")
    cold = _run_mode(monkeypatch, "fused", wl)
    _assert_parity(fast, cold)


def test_resident_context_parity_across_bandwidth_churn(monkeypatch):
    """A bandwidth-only delta between waves rebases the resident context
    (comm caches drop, core scan state survives); a kill between waves
    dirties the device.  Decisions still match the cold walk."""

    def wl(tb):
        return [[make_task("svm", origin=tb.edges[0], deadline=0.5,
                           release_time=0.01 * i),
                 make_task("mlp", origin=tb.edges[1], deadline=0.5,
                           release_time=0.01 * i)]
                for i in range(4)]

    def churn(tb, i):
        tb.graph.set_bandwidth(f"link_{tb.edges[1]}", 3e6 + 1e6 * i)

    monkeypatch.setenv("REPRO_SERVE_FASTPATH", "1")
    fast = _run_mode(monkeypatch, "fused", wl, churn=churn)
    monkeypatch.setenv("REPRO_SERVE_FASTPATH", "0")
    cold = _run_mode(monkeypatch, "fused", wl, churn=churn)
    _assert_parity(fast, cold)


def test_resident_context_identity_and_oracle_off(monkeypatch):
    """The root orchestrator keeps one ``_BatchContext`` across
    ``map_batch`` calls; ``REPRO_SERVE_FASTPATH=0`` restores the per-batch
    cold behaviour (no resident state is retained at all)."""
    monkeypatch.setenv("REPRO_FUSED_WALK", "1")
    monkeypatch.setenv("REPRO_SHARDED_WALK", "0")
    monkeypatch.setenv("REPRO_SERVE_FASTPATH", "1")
    tb = build_testbed(edge_counts={"orin_agx": 1, "orin_nano": 1},
                       server_counts={"server1": 1})
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    root.map_batch([make_task("svm", origin=tb.edges[0], deadline=0.5)],
                   now=0.0, route=True)
    ctx = root._resident_ctx
    assert ctx is not None
    root.map_batch([make_task("mlp", origin=tb.edges[1], deadline=0.5)],
                   now=0.01, route=True)
    assert root._resident_ctx is ctx       # reused, not rebuilt
    # bandwidth-only churn rebases the same context onto the new snapshot
    tb.graph.set_bandwidth(f"link_{tb.edges[0]}", 5e6)
    root.map_batch([make_task("svm", origin=tb.edges[0], deadline=0.5)],
                   now=0.02, route=True)
    assert root._resident_ctx is ctx
    assert ctx.comp is tb.graph.compiled()
    # the oracle switch disables residency entirely
    monkeypatch.setenv("REPRO_SERVE_FASTPATH", "0")
    root2 = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    root2.map_batch([make_task("svm", origin=tb.edges[0], deadline=0.5)],
                    now=0.0, route=True)
    assert root2._resident_ctx is None
