"""Traverser tests (paper §3.4): contention intervals, predictions,
communication modeling, queueing."""
import numpy as np
import pytest

from repro.core import (DecoupledSlowdown, NoSlowdown, Task, TaskGraph,
                        Traverser, build_testbed)
from repro.core.topology import make_task


@pytest.fixture(scope="module")
def tb():
    return build_testbed(edge_counts={"orin_agx": 1, "orin_nano": 1},
                         server_counts={"server1": 1})


@pytest.fixture()
def trav(tb):
    return Traverser(tb.graph)


def test_serial_chain_sums(tb, trav):
    """No contention: chain latency == sum of standalone times."""
    e = tb.edges[0]
    cfg = TaskGraph()
    t1, t2 = make_task("capture", origin=e), make_task("display", origin=e)
    cfg.chain([t1, t2])
    tl = trav.traverse(cfg, {t1.uid: f"{e}.cpu0", t2.uid: f"{e}.cpu0"})
    exp = tb.graph.nodes[f"{e}.cpu0"].predict(t1) + \
        tb.graph.nodes[f"{e}.cpu0"].predict(t2)
    assert tl.makespan == pytest.approx(exp, rel=1e-9)


def test_parallel_tasks_with_contention_slow_down(tb, trav):
    e = tb.edges[0]
    cfg = TaskGraph()
    a, b = make_task("dnn", origin=e), make_task("dnn", origin=e)
    cfg.add(a)
    cfg.add(b)
    tl = trav.traverse(cfg, {a.uid: f"{e}.gpu", b.uid: f"{e}.gpu"})
    sa = tb.graph.nodes[f"{e}.gpu"].predict(a)
    # both run concurrently at ~0.66x speed -> each takes sa/0.66
    assert tl.makespan == pytest.approx(sa / 0.66, rel=0.05)
    assert tl.slowdown_of(a) > 1.4
    assert tl.n_intervals >= 2


def test_blind_model_sees_no_contention(tb):
    e = tb.edges[0]
    blind = Traverser(tb.graph, slowdown=NoSlowdown(tb.graph))
    cfg = TaskGraph()
    a, b = make_task("dnn", origin=e), make_task("dnn", origin=e)
    cfg.add(a)
    cfg.add(b)
    tl = blind.traverse(cfg, {a.uid: f"{e}.gpu", b.uid: f"{e}.gpu"})
    sa = tb.graph.nodes[f"{e}.gpu"].predict(a)
    assert tl.makespan == pytest.approx(sa, rel=1e-6)


def test_contention_interval_release(tb, trav):
    """A short co-runner finishing mid-way restores the long task's speed:
    the long task's total busy time must be < full-contention bound."""
    e = tb.edges[0]
    cfg = TaskGraph()
    long = make_task("knn", origin=e)     # 14 ms standalone on gpu
    short = make_task("mlp", origin=e)    # 5 ms standalone
    cfg.add(long)
    cfg.add(short)
    tl = trav.traverse(cfg, {long.uid: f"{e}.gpu", short.uid: f"{e}.gpu"})
    sa_long = tb.graph.nodes[f"{e}.gpu"].predict(long)
    full_contention = sa_long * tl.slowdown_of(short)
    assert tl.finish[long.uid] < full_contention + sa_long  # regained speed
    assert tl.finish[short.uid] < tl.finish[long.uid]


def test_cross_device_transfer_charged(tb, trav):
    e, s = tb.edges[0], tb.servers[0]
    cfg = TaskGraph()
    a = make_task("render", origin=e, input_bytes=250e3)
    cfg.add(a)
    tl = trav.traverse(cfg, {a.uid: f"{s}.gpu"})
    sa = tb.graph.nodes[f"{s}.gpu"].predict(a)
    comm = tb.graph.transfer_time(e, s, 250e3)
    assert tl.makespan == pytest.approx(sa + comm, rel=0.05)
    assert tl.comm[a.uid] == pytest.approx(comm, rel=0.05)


def test_concurrent_transfers_share_link(tb, trav):
    """Two transfers over the same edge uplink halve each other's bandwidth."""
    e, s = tb.edges[0], tb.servers[0]
    nbytes = 5e6
    single = TaskGraph()
    a = make_task("render", origin=e, input_bytes=nbytes)
    single.add(a)
    tl1 = trav.traverse(single, {a.uid: f"{s}.gpu"})
    t_single = tl1.comm[a.uid]

    both = TaskGraph()
    b1 = make_task("render", origin=e, input_bytes=nbytes)
    b2 = make_task("render", origin=e, input_bytes=nbytes)
    both.add(b1)
    both.add(b2)
    tl2 = trav.traverse(both, {b1.uid: f"{s}.gpu", b2.uid: f"{s}.gpu"})
    t_shared = max(tl2.comm[b1.uid], tl2.comm[b2.uid])
    assert t_shared > 1.6 * t_single


def test_max_tenancy_queues(tb, trav):
    e = tb.edges[0]
    pu = tb.graph.nodes[f"{e}.vic"]       # max_tenancy=2
    cfg = TaskGraph()
    ts = [make_task("encode", origin=e) for _ in range(4)]
    for t in ts:
        cfg.add(t)
    tl = trav.traverse(cfg, {t.uid: pu.name for t in ts})
    waits = sorted(tl.queue_wait[t.uid] for t in ts)
    assert waits[0] == 0.0 and waits[1] == 0.0      # first two start at once
    assert waits[2] > 0.0 and waits[3] > 0.0        # rest queue


def test_background_tasks_contend(tb, trav):
    e = tb.edges[0]
    bg = make_task("render", origin=e)
    cfg = TaskGraph()
    a = make_task("dnn", origin=e)
    cfg.add(a)
    tl = trav.traverse(cfg, {a.uid: f"{e}.gpu"},
                       background=[(bg, f"{e}.gpu", 0.050)])
    assert tl.slowdown_of(a) > 1.0
    assert tl.finish[bg.uid] > 0.0      # projected finish reported


def test_deadline_checks(tb, trav):
    e = tb.edges[0]
    cfg = TaskGraph()
    ok = make_task("capture", origin=e, deadline=0.1)
    late = make_task("render", origin=e, deadline=1e-6)
    cfg.add(ok)
    cfg.add(late)
    tl = trav.traverse(cfg, {ok.uid: f"{e}.cpu0", late.uid: f"{e}.gpu"})
    assert tl.deadline_met(ok)
    assert not tl.deadline_met(late)


def test_predict_task_closed_form(tb, trav):
    e = tb.edges[0]
    t = make_task("dnn", origin=e)
    active = [(make_task("dnn"), f"{e}.gpu")]
    pred = trav.predict_task(t, f"{e}.gpu", active)
    sa = tb.graph.nodes[f"{e}.gpu"].predict(t)
    assert pred.standalone == pytest.approx(sa)
    assert pred.factor > 1.4
    assert pred.total == pytest.approx(sa * pred.factor + pred.comm)


def test_dag_dependencies_respected(tb, trav):
    e = tb.edges[0]
    cfg = TaskGraph()
    a = make_task("capture", origin=e)
    b1 = make_task("svm", origin=e)
    b2 = make_task("mlp", origin=e)
    c = make_task("display", origin=e)
    cfg.add(a)
    cfg.add(b1, deps=[a])
    cfg.add(b2, deps=[a])
    cfg.add(c, deps=[b1, b2])
    m = {a.uid: f"{e}.cpu0", b1.uid: f"{e}.gpu", b2.uid: f"{e}.cpu1",
         c.uid: f"{e}.cpu0"}
    tl = trav.traverse(cfg, m)
    assert tl.start[b1.uid] >= tl.finish[a.uid]
    assert tl.start[b2.uid] >= tl.finish[a.uid]
    assert tl.start[c.uid] >= max(tl.finish[b1.uid], tl.finish[b2.uid])


def test_missing_mapping_raises(tb, trav):
    cfg = TaskGraph()
    t = make_task("mm")
    cfg.add(t)
    with pytest.raises(KeyError):
        trav.traverse(cfg, {})


def test_cycle_detection():
    cfg = TaskGraph()
    a, b = Task("x"), Task("y")
    cfg.add(a)
    cfg.add(b, deps=[a])
    cfg.add_dep(b, a)
    with pytest.raises(ValueError):
        cfg.topological()
