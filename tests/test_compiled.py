"""CompiledHWGraph: exact parity with the object-graph reference path.

The compiled arrays (core/compiled.py) must reproduce the authoring-layer
algorithms bit-for-bit (tolerance 1e-9): nearest common resources, transfer
times over the routable nodes, pairwise and pooled slowdown factors, and
the Orchestrator's batched candidate checks — plus snapshot invalidation
on every topology mutation hook.
"""
import numpy as np
import pytest

from repro.core import (DecoupledSlowdown, Traverser, build_testbed,
                        heye_params, truth_params)
from repro.core.hwgraph import ProcessingUnit
from repro.core.topology import make_task

TOL = 1e-9


@pytest.fixture(autouse=True)
def _strict_f64_aggregation():
    """1e-9 parity is a float64 contract: pin the numpy aggregation path so
    these tests hold even on a TPU host (where the fp32 Pallas kernel would
    otherwise be auto-selected; its own tolerance is tested separately)."""
    from repro.core import slowdown as sdmod
    prev = sdmod._AGGREGATE
    sdmod._AGGREGATE = sdmod._aggregate_np
    yield
    sdmod._AGGREGATE = prev


@pytest.fixture(scope="module")
def tb():
    # the paper's Orin/Xavier testbed: every edge kind + all three servers
    return build_testbed(edge_counts={"orin_agx": 1, "xavier_agx": 1,
                                      "orin_nano": 1, "xavier_nx": 2},
                         server_counts={"server1": 1, "server2": 1,
                                        "server3": 1})


def _pus(g):
    return [n.name for n in g.nodes.values() if isinstance(n, ProcessingUnit)]


def _pool(tb, n_servers=True):
    kinds = ("dnn", "mm", "knn", "svm", "render", "encode", "reproject")
    pool = []
    for i, e in enumerate(tb.edges):
        for short in ("cpu0", "cpu1", "gpu", "dla", "pva", "vic"):
            pool.append((make_task(kinds[(i + len(pool)) % len(kinds)]),
                         f"{e}.{short}"))
    if n_servers:
        for s in tb.servers:
            pool.append((make_task("knn"), f"{s}.gpu"))
            pool.append((make_task("mlp"), f"{s}.cpu"))
    return pool


# ---------------------------------------------------------------------------
# nearest common resource
# ---------------------------------------------------------------------------
def test_ncr_matrix_matches_object_paths(tb):
    g = tb.graph
    comp = g.compiled()
    pus = _pus(g)
    for a in pus:
        pa = g.nodes[a].get_compute_path()
        for b in pus:
            pb = set(g.nodes[b].get_compute_path())
            expected = next((r for r in pa if r in pb), None)
            assert comp.nearest_common_resource(a, b) == expected, (a, b)


def test_ncr_known_contention_points(tb):
    comp = tb.graph.compiled()
    e = tb.edges[0]
    # Fig. 4: DLA and PVA meet at the vision SRAM; same-device CPU clusters
    # meet at L3; CPU and GPU meet at the LLC; cross-device pairs share nothing
    assert comp.nearest_common_resource(f"{e}.dla", f"{e}.pva") == f"{e}.sram"
    assert comp.nearest_common_resource(f"{e}.cpu0", f"{e}.cpu1") == f"{e}.l3"
    assert comp.nearest_common_resource(f"{e}.cpu0", f"{e}.gpu") == f"{e}.llc"
    e2 = tb.edges[1]
    assert comp.nearest_common_resource(f"{e}.gpu", f"{e2}.gpu") is None


# ---------------------------------------------------------------------------
# transfer matrices
# ---------------------------------------------------------------------------
def test_transfer_time_parity(tb):
    g = tb.graph
    comp = g.compiled()
    names = tb.edges + tb.servers
    for nbytes in (0.0, 1e3, 5e6):
        for s in names:
            for d in names:
                assert comp.transfer_time(s, d, nbytes) == pytest.approx(
                    g.transfer_time(s, d, nbytes), abs=TOL, rel=TOL)


def test_transfer_unreachable_raises_like_object_path(tb):
    g = tb.graph
    comp = g.compiled()
    # cluster GROUPs have no interconnects: both layers must raise
    with pytest.raises(KeyError):
        g.transfer_time(tb.edges[0], "edge_cluster", 1.0)
    with pytest.raises(KeyError):
        comp.transfer_time(tb.edges[0], "edge_cluster", 1.0)


def test_route_edges_identity(tb):
    g = tb.graph
    comp = g.compiled()
    e, s = tb.edges[0], tb.servers[0]
    # the Traverser's bandwidth sharing keys transfers by id(edge): the
    # compiled routes must hand out the *same* EdgeAttr objects
    assert [id(x) for x in comp.route_edges(e, s)] == \
        [id(x) for x in g.route_edges(e, s)]


# ---------------------------------------------------------------------------
# slowdown factors
# ---------------------------------------------------------------------------
def test_factor_batch_parity(tb):
    sd = DecoupledSlowdown(tb.graph, heye_params())
    pool = _pool(tb)
    got = sd.factor_batch(pool)
    want = np.array([sd.factor(t, p, pool) for t, p in pool])
    np.testing.assert_allclose(got, want, atol=TOL, rtol=TOL)


def test_slowdown_matrix_pairwise_parity(tb):
    sd = DecoupledSlowdown(tb.graph, truth_params(noise=0.0))
    pool = _pool(tb, n_servers=False)
    mat = sd.slowdown_matrix(pool)
    assert mat.shape == (len(pool), len(pool))
    for i, (ti, pi) in enumerate(pool):
        for j, (tj, pj) in enumerate(pool):
            assert mat[i, j] == pytest.approx(
                sd.factor(ti, pi, [(tj, pj)]), abs=TOL, rel=TOL)
    np.testing.assert_allclose(np.diag(mat), 1.0)


def test_factors_with_candidates_parity(tb):
    sd = DecoupledSlowdown(tb.graph, heye_params())
    task = make_task("render", origin=tb.edges[0])
    active = _pool(tb)[:14]
    cands = [f"{tb.edges[0]}.{s}" for s in ("cpu0", "cpu1", "gpu", "vic")] \
        + [f"{tb.servers[0]}.gpu"]
    new_f, act_f = sd.factors_with_candidates(task, cands, active)
    for c, p in enumerate(cands):
        assert new_f[c] == pytest.approx(sd.factor(task, p, list(active)),
                                         abs=TOL, rel=TOL)
        pool_c = list(active) + [(task, p)]
        for a, (t, q) in enumerate(active):
            assert act_f[c, a] == pytest.approx(sd.factor(t, q, pool_c),
                                                abs=TOL, rel=TOL)


def test_predict_active_with_parity(tb):
    trav = Traverser(tb.graph)
    active = _pool(tb)[:10]
    new = make_task("dnn", origin=tb.edges[0])
    pu = f"{tb.edges[0]}.gpu"
    got = trav.predict_active_with(new, pu, active)
    pool = list(active) + [(new, pu)]
    for t, p in active:
        others = [(t2, p2) for t2, p2 in pool if t2.uid != t.uid]
        assert got[t.uid] == pytest.approx(
            trav.slowdown.factor(t, p, others), abs=TOL, rel=TOL)


def test_noisy_truth_model_still_batches_deterministically(tb):
    """The ground-truth params carry noise>0 but no rng: the batch path must
    stay on the vectorized branch and match the scalar path exactly."""
    sd = DecoupledSlowdown(tb.graph, truth_params())
    assert sd.rng is None
    pool = _pool(tb, n_servers=False)[:12]
    got = sd.factor_batch(pool)
    want = np.array([sd.factor(t, p, pool) for t, p in pool])
    np.testing.assert_allclose(got, want, atol=TOL, rtol=TOL)


# ---------------------------------------------------------------------------
# invalidation on topology mutation
# ---------------------------------------------------------------------------
def test_mark_dead_invalidates_and_reconverges():
    tb = build_testbed(edge_counts={"orin_agx": 2},
                       server_counts={"server1": 1})
    g = tb.graph
    e = tb.edges[0]
    before = g.compiled()
    assert g.compiled() is before           # snapshot is reused while valid
    g.mark_dead(e)
    after = g.compiled()
    assert after is not before
    assert not after.pu_alive[after.pu_index[f"{e}.gpu"]]
    g.mark_alive(e)
    revived = g.compiled()
    assert revived is not after
    assert revived.pu_alive[revived.pu_index[f"{e}.gpu"]]
    # parity holds against the freshly mutated object graph
    sd = DecoupledSlowdown(g, heye_params())
    a, b = make_task("dnn"), make_task("dnn")
    pool = [(a, f"{e}.gpu"), (b, f"{e}.dla")]
    np.testing.assert_allclose(
        sd.factor_batch(pool),
        [sd.factor(a, f"{e}.gpu", pool), sd.factor(b, f"{e}.dla", pool)],
        atol=TOL, rtol=TOL)


def test_slowdown_kernel_matches_numpy_oracle():
    """Pallas factor-aggregation kernel (interpret mode) vs ref oracle."""
    pytest.importorskip("jax")
    from repro.kernels.ref import slowdown_factors_ref
    from repro.kernels.slowdown_kernel import (slowdown_factors,
                                               slowdown_factors_pallas)
    rng = np.random.default_rng(0)
    for n, r in ((1, 3), (5, 8), (130, 6)):
        x = rng.uniform(0.0, 3.0, (n, r)) * (rng.random((n, r)) > 0.4)
        beta = rng.uniform(0.0, 0.5, r)
        beta[0] = 0.0                       # inactive-resource branch
        mem = rng.uniform(0.0, 1.0, n)
        mt = rng.uniform(0.0, 1.0, n) * (rng.random(n) > 0.5)
        ref = slowdown_factors_ref(x, beta, mem, mt, 0.12)
        pal = np.asarray(slowdown_factors_pallas(x, beta, mem, mt, 0.12,
                                                 interpret=True))
        np.testing.assert_allclose(pal, ref, rtol=2e-5, atol=2e-5)  # fp32
        # the backend selector must agree with the oracle exactly off-TPU
        sel = slowdown_factors(x, beta, mem, mt, 0.12)
        import jax
        if jax.default_backend() != "tpu":
            np.testing.assert_array_equal(sel, ref)


def test_set_bandwidth_invalidates_transfer_matrices():
    tb = build_testbed(edge_counts={"orin_agx": 1},
                       server_counts={"server1": 1})
    g = tb.graph
    e, s = tb.edges[0], tb.servers[0]
    before = g.compiled()
    t0 = before.transfer_time(e, s, 10e6)
    g.set_bandwidth(f"link_{e}", 1e6)
    after = g.compiled()
    assert after is not before
    t1 = after.transfer_time(e, s, 10e6)
    assert t1 > t0
    assert t1 == pytest.approx(g.transfer_time(e, s, 10e6), abs=TOL, rel=TOL)


# ---------------------------------------------------------------------------
# layered COW route tables: topology layer vs bandwidth overlay
# ---------------------------------------------------------------------------
def _route_parity(patched, fresh, names, nb=5e6, tol=TOL):
    """Every routable pair must price identically on the delta-patched
    snapshot and a fresh recompile (KeyError behaviour included)."""
    for s in names:
        for d in names:
            try:
                want = fresh.transfer_time(s, d, nb)
            except KeyError:
                with pytest.raises(KeyError):
                    patched.transfer_time(s, d, nb)
                continue
            got = patched.transfer_time(s, d, nb)
            assert got == pytest.approx(want, abs=tol, rel=tol), (s, d)


@pytest.mark.parametrize("seed", range(4))
def test_layered_cow_random_interleaving_parity(seed):
    """Property-style oracle: a random interleaving of bandwidth batches,
    deaths and revivals over lazily part-built route rows — with every
    intermediate snapshot kept alive as a sharer — must stay bit-identical
    to a fresh recompile of the final graph."""
    import random

    from repro.core import Churn
    from repro.core.compiled import CompiledHWGraph
    rng = random.Random(seed)
    tb = build_testbed(edge_counts={"orin_agx": 1, "xavier_agx": 1,
                                    "orin_nano": 1},
                       server_counts={"server1": 1, "server2": 1})
    g = tb.graph
    names = tb.edges + tb.servers
    links = [f"link_{n}" for n in names]
    nominal = {}
    for adj in g._adj.values():
        for _, e in adj:
            if e.name in links:
                nominal.setdefault(e.name, e.bandwidth)
    sharers = [g.compiled()]                 # >= 2 sharers at every step
    for _ in range(12):
        comp = g.compiled()
        # lazily build a few rows on the current snapshot
        for s in rng.sample(names, 2):
            try:
                comp.transfer_time(s, rng.choice(names), 5e6)
            except KeyError:
                pass
        op = rng.random()
        if op < 0.55:
            entries = tuple((ln, nominal[ln] * rng.uniform(0.05, 1.5))
                            for ln in (rng.choice(links)
                                       for _ in range(rng.randint(1, 3))))
            g.apply_churn(Churn(bandwidth=entries))
        elif op < 0.8:
            alive = [n for n in names if g.nodes[n].alive]
            if len(alive) > 2:
                g.apply_churn(Churn(dead=(rng.choice(alive),)))
        else:
            dead = [n for n in names if not g.nodes[n].alive]
            if dead:
                g.apply_churn(Churn(alive=(rng.choice(dead),)))
        sharers.append(g.compiled())
    _route_parity(g.compiled(), CompiledHWGraph(g), names)


def test_bandwidth_overlay_shares_topology_layer():
    from repro.core import Churn
    tb = build_testbed(edge_counts={"orin_agx": 2},
                       server_counts={"server1": 1})
    g = tb.graph
    e0, e1, s = tb.edges[0], tb.edges[1], tb.servers[0]
    old = g.compiled()
    t_before = old.transfer_time(e0, s, 10e6)     # lazy row build
    h0, o0 = g.route_holder_copies, g.route_overlay_copies
    g.apply_churn(Churn(bandwidth=((f"link_{e0}", 2e6),)))
    new = g.compiled()
    assert new is not old and new._rt is not old._rt
    assert new._rt.topo is old._rt.topo           # topology layer shared
    assert g.route_holder_copies == h0            # no O(D^2) copy
    assert g.route_overlay_copies == o0 + 1
    # the stale sharer keeps its pre-churn pricing on built rows; the
    # patched snapshot prices the degraded uplink
    assert old.transfer_time(e0, s, 10e6) == pytest.approx(
        t_before, abs=TOL, rel=TOL)
    assert new.transfer_time(e0, s, 10e6) > t_before
    # a row built lazily on the stale sharer writes through to the shared
    # topology layer: the patched snapshot resolves it without rebuilding
    t_e1 = old.transfer_time(e1, s, 10e6)
    assert new.transfer_time(e1, s, 10e6) == pytest.approx(
        t_e1, abs=TOL, rel=TOL)


def test_bandwidth_delta_on_unreferenced_links_shares_whole_table():
    from repro.core import Churn
    tb = build_testbed(edge_counts={"orin_agx": 2},
                       server_counts={"server1": 1})
    g = tb.graph
    comp = g.compiled()                           # no rows built yet
    o0, h0 = g.route_overlay_copies, g.route_holder_copies
    g.apply_churn(Churn(bandwidth=((f"link_{tb.edges[1]}", 5e6),)))
    new = g.compiled()
    assert new is not comp
    assert new._rt is comp._rt                    # zero-copy share
    assert (g.route_overlay_copies, g.route_holder_copies) == (o0, h0)
    # rows built after the share price the post-churn bandwidths
    from repro.core.compiled import CompiledHWGraph
    _route_parity(new, CompiledHWGraph(g), tb.edges + tb.servers)


def test_sharded_slices_share_topology_after_bandwidth_delta():
    from repro.core import Churn
    from repro.core.compiled import CompiledHWGraph, ShardedHWGraph
    tb = build_testbed(edge_counts={"orin_agx": 2},
                       server_counts={"server1": 1})
    g = tb.graph
    e0, s = tb.edges[0], tb.servers[0]
    comp = g.compiled()
    comp.transfer_time(e0, s, 5e6)
    sh = comp.sharded({"edge": list(tb.edges), "server": list(tb.servers)})
    assert isinstance(sh, ShardedHWGraph)
    assert sh.routes is comp._rt
    g.apply_churn(Churn(bandwidth=((f"link_{e0}", 3e6),)))
    comp2 = g.compiled()
    # the sharded view and the patched snapshot still share one topology
    # layer; only the bandwidth overlay diverged
    assert comp2._rt.topo is sh.routes.topo
    assert g.route_holder_copies == 0
    _route_parity(comp2, CompiledHWGraph(g), tb.edges + tb.servers)


def test_overlay_compaction_bounds_dirty_on_long_runs():
    """A long bandwidth-volatile run keeps the overlay bounded: once the
    dirty-link set reaches the compaction threshold and no other snapshot
    shares the topology layer, the overlay folds into it (counter bumps),
    and pricing stays bit-identical to a fresh recompile."""
    import gc

    from repro.core import Churn
    from repro.core.compiled import _OVERLAY_COMPACT_DIRTY, CompiledHWGraph
    tb = build_testbed(edge_counts={"orin_agx": 40, "xavier_agx": 30},
                       server_counts={"server1": 1})
    g = tb.graph
    s = tb.servers[0]
    links = [f"link_{e}" for e in tb.edges]
    assert len(links) > _OVERLAY_COMPACT_DIRTY
    # materialize one route per edge so every uplink's link is crossed by
    # a built row (deltas must overlay-copy, not zero-copy share)
    for e in tb.edges:
        g.compiled().transfer_time(e, s, 5e6)
    c0 = g.route_overlay_compactions
    peak = 0
    for k, ln in enumerate(links):
        gc.collect()      # drop dead sharers so sole ownership is exact
        g.apply_churn(Churn(bandwidth=((ln, 4e6 + 1e3 * k),)))
        peak = max(peak, len(g.compiled()._rt.dirty))
    assert g.route_overlay_compactions > c0
    assert peak <= _OVERLAY_COMPACT_DIRTY          # bounded, not monotone
    assert len(g.compiled()._rt.dirty) < len(links)
    _route_parity(g.compiled(), CompiledHWGraph(g),
                  tb.edges[:6] + [tb.edges[-1], s])
